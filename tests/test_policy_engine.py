"""Fault-policy engine tests (ISSUE-9 tentpole).

Covers the adaptive decision table (docs/policies.md), the fixed
baselines' memorylessness, the post-fallback checkpoint contracts
(exactly ONE save per fallback burst; crash between decision and save
leaves the prior checkpoint restorable), the trainer integration, and
the policy-comparison campaign's determinism (byte-identical audit
trails on same-seed reruns).
"""

import os
import subprocess
import sys
import types

import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.core.fabric import build_cluster
from repro.policy import (FIXED_POLICIES, POLICIES, FaultPolicyEngine,
                          PolicyConfig)
from repro.scenarios import SCENARIOS, run_policy_matrix, run_scenario

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _engine(policy="adaptive", store=None, libs=(), **cfg):
    cluster = build_cluster()
    eng = FaultPolicyEngine(policy, PolicyConfig(**cfg) if cfg else None)
    eng.attach(cluster, list(libs), store=store)
    return cluster, eng


def _responses(eng):
    return [d.response for d in eng.decisions]


class _FakeQP:
    """Just enough ShiftQP surface for lifecycle-hook tests."""

    def __init__(self, cluster, gid="host0/mlx5_0"):
        nic = cluster.nic_by_gid[gid]
        self.default = types.SimpleNamespace(
            ctx=types.SimpleNamespace(nic=nic))
        self.flap_times = []


class _FakeLib:
    def __init__(self, cluster):
        self.shift_qps = [_FakeQP(cluster)]
        self.stats = types.SimpleNamespace(fallbacks=0)
        self.policy = None

    def attach_policy(self, engine):
        self.policy = engine


# ---------------------------------------------------------------------------
# adaptive decision table
# ---------------------------------------------------------------------------

def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        FaultPolicyEngine("yolo")


@pytest.mark.parametrize("kind,arg,expected", [
    ("bw_degrade", 0.05, "shrink"),      # heavy: <= shrink_bw_frac
    ("bw_degrade", 0.25, "shrink"),      # boundary is inclusive
    ("bw_degrade", 0.5, "demote"),       # moderate
    ("lat_inflate", 25.0, "shrink"),     # heavy: >= shrink_lat_mult
    ("lat_inflate", 2.0, "demote"),      # moderate
    ("nic_down", None, "shift_fallback"),
    ("port_down", None, "shift_fallback"),
    ("link_down", None, "shift_fallback"),
])
def test_adaptive_fault_responses(kind, arg, expected):
    cluster, eng = _engine("adaptive")
    cluster.apply_fault(kind, "host0/mlx5_0", arg)
    assert _responses(eng) == [expected], eng.decisions


@pytest.mark.parametrize("down,up", [
    ("nic_down", "nic_up"), ("port_down", "port_up"),
    ("link_down", "link_up"), ("bw_degrade", "bw_restore"),
    ("lat_inflate", "lat_restore"),
])
def test_adaptive_restores_readmit(down, up):
    cluster, eng = _engine("adaptive")
    arg = {"bw_degrade": 0.5, "lat_inflate": 2.0}.get(down)
    cluster.apply_fault(down, "host0/mlx5_0", arg)
    cluster.apply_fault(up, "host0/mlx5_0")
    assert _responses(eng)[-1] == "readmit"


def test_rail_selector_decides_per_nic():
    """A correlated rail fault yields one decision per affected NIC —
    the audit trail distinguishes the two hosts' rails."""
    cluster, eng = _engine("adaptive")
    cluster.apply_fault("bw_degrade", "rail:0", 0.05)
    assert _responses(eng) == ["shrink", "shrink"]
    assert {d.signals.target for d in eng.decisions} == \
        {"host0/mlx5_0", "host1/mlx5_0"}


def test_decisions_record_signal_snapshots():
    cluster, eng = _engine("adaptive")
    cluster.apply_fault("nic_down", "host1/mlx5_1")
    (d,) = eng.decisions
    assert d.trigger == "fault:nic_down"
    assert d.signals.rail == 1
    assert d.signals.target == "host1/mlx5_1"
    assert isinstance(d.as_tuple(), tuple)
    assert eng.audit() == [d.as_tuple()]


# ---------------------------------------------------------------------------
# fixed baselines: namesake response, memoryless
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", FIXED_POLICIES)
def test_fixed_policy_applies_namesake(policy):
    cluster, eng = _engine(policy)
    cluster.apply_fault("nic_down", "host0/mlx5_0")
    cluster.apply_fault("bw_degrade", "host0/mlx5_1", 0.1)
    assert _responses(eng) == [policy, policy]


@pytest.mark.parametrize("policy", FIXED_POLICIES)
def test_fixed_policy_never_readmits(policy):
    """Fixed baselines are memoryless single-response policies: the
    restore signal undoes nothing (undoing is what adaptive adds)."""
    cluster, eng = _engine(policy)
    cluster.apply_fault("nic_down", "host0/mlx5_0")
    n = len(eng.decisions)
    cluster.apply_fault("nic_up", "host0/mlx5_0")
    assert len(eng.decisions) == n
    assert "readmit" not in _responses(eng)


# ---------------------------------------------------------------------------
# fallback lifecycle: checkpoint rate limit + storm detection
# ---------------------------------------------------------------------------

def test_calm_fallback_checkpoints_once_per_burst():
    """Exactly ONE post-fallback save per fallback burst: the first
    fallback decides "checkpoint", further fallbacks inside
    ``min_ckpt_interval`` ride in place, and the next burst (after the
    interval) checkpoints again."""
    cluster = build_cluster()
    lib = _FakeLib(cluster)
    eng = FaultPolicyEngine("adaptive", PolicyConfig(min_ckpt_interval=25e-3))
    eng.attach(cluster, [lib])
    qp = lib.shift_qps[0]
    eng.on_lifecycle(lib, "fallback", qp)
    eng.on_lifecycle(lib, "fallback", qp)     # same burst: rate-limited
    cluster.sim.run(until=0.05)               # interval expires
    eng.on_lifecycle(lib, "fallback", qp)     # new burst
    assert _responses(eng) == ["checkpoint", "shift_fallback", "checkpoint"]
    assert "ckpt rate-limited" in eng.decisions[1].detail


def test_flap_storm_shrinks_instead_of_checkpointing():
    cluster = build_cluster()
    lib = _FakeLib(cluster)
    eng = FaultPolicyEngine("adaptive",
                            PolicyConfig(flap_window=30e-3, storm_flaps=3))
    eng.attach(cluster, [lib])
    qp = lib.shift_qps[0]
    qp.flap_times = [0.001, 0.002, 0.003]     # 3 flaps in the window
    eng.on_lifecycle(lib, "fallback", qp)
    assert _responses(eng) == ["shrink"]
    assert eng.decisions[0].signals.recent_flaps == 3


def test_failed_lifecycle_shrinks():
    cluster = build_cluster()
    lib = _FakeLib(cluster)
    eng = FaultPolicyEngine("adaptive")
    eng.attach(cluster, [lib])
    eng.on_lifecycle(lib, "failed", lib.shift_qps[0])
    assert _responses(eng) == ["shrink"]
    assert eng.consume_trainer_actions()["shrink"] is True


def test_recovery_lifecycle_readmits():
    cluster = build_cluster()
    lib = _FakeLib(cluster)
    eng = FaultPolicyEngine("adaptive")
    eng.attach(cluster, [lib])
    eng.on_lifecycle(lib, "recovery", lib.shift_qps[0])
    assert _responses(eng) == ["readmit"]


# ---------------------------------------------------------------------------
# checkpoint actuation: exactly-once per burst, crash windows
# ---------------------------------------------------------------------------

def test_store_sees_one_save_per_burst(tmp_path):
    store = CheckpointStore(str(tmp_path / "ckpt"), keep=4)
    cluster = build_cluster()
    lib = _FakeLib(cluster)
    eng = FaultPolicyEngine("adaptive", PolicyConfig(min_ckpt_interval=25e-3))
    eng.attach(cluster, [lib], store=store)
    qp = lib.shift_qps[0]
    for _ in range(4):                        # a flap train, one burst
        eng.on_lifecycle(lib, "fallback", qp)
    cluster.sim.run(until=0.01)               # deferred save event fires
    assert eng.saves == 1
    assert store.list_steps() == [1]
    _, meta = store.restore({"policy_state": np.zeros(1, np.float32)})
    assert meta["reason"] == "post-fallback"


def test_fixed_checkpoint_baseline_save_storms(tmp_path):
    """The fixed ``checkpoint`` baseline is deliberately NOT
    rate-limited — it exists to price the save storm the adaptive rate
    limit avoids."""
    store = CheckpointStore(str(tmp_path / "ckpt"), keep=8)
    cluster = build_cluster()
    lib = _FakeLib(cluster)
    eng = FaultPolicyEngine("checkpoint")
    eng.attach(cluster, [lib], store=store)
    qp = lib.shift_qps[0]
    for _ in range(3):
        eng.on_lifecycle(lib, "fallback", qp)
    cluster.sim.run(until=0.01)
    assert eng.saves == 3
    assert store.list_steps() == [1, 2, 3]


_CRASH_CHILD = """
import os, sys
import numpy as np
from repro.core.fabric import build_cluster
from repro.checkpoint.store import CheckpointStore
from repro.policy import FaultPolicyEngine

store = CheckpointStore({root!r}, keep=2, async_save={async_save})
store.save(1, {{"w": np.full((32,), 7.0, np.float32)}}, {{"reason": "base"}})
store.wait()
cluster = build_cluster()
eng = FaultPolicyEngine("adaptive")
eng.attach(cluster, [], store=store)
eng._act_checkpoint(cluster.sim.now)   # decision recorded, save deferred
{extra}
os._exit(0)                            # crash {when}
"""


def _run_crash_child(code, tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    subprocess.run([sys.executable, "-c", code], env=env,
                   cwd=str(tmp_path), timeout=120)


def test_crash_between_decision_and_save_keeps_prior_restorable(tmp_path):
    """A crash injected BETWEEN the policy decision and the deferred
    save must leave the prior committed checkpoint restorable — the
    decision alone touches nothing on disk."""
    root = str(tmp_path / "ckpt")
    _run_crash_child(_CRASH_CHILD.format(
        root=root, async_save=False, extra="",
        when="before the deferred sim event runs"), tmp_path)
    store = CheckpointStore(root, keep=2)
    assert store.list_steps() == [1]
    restored, meta = store.restore({"w": np.zeros(32, np.float32)})
    assert meta["reason"] == "base"
    np.testing.assert_array_equal(restored["w"],
                                  np.full((32,), 7.0, np.float32))


def test_crash_during_policy_save_keeps_prior_restorable(tmp_path):
    """``os._exit`` while the policy's async save is in flight: the
    marker-last commit protocol keeps every step ``list_steps`` reports
    restorable — a torn policy save is invisible."""
    root = str(tmp_path / "ckpt")
    _run_crash_child(_CRASH_CHILD.format(
        root=root, async_save=True,
        extra="cluster.sim.run(until=0.01)   # save issued to the writer",
        when="mid-save"), tmp_path)
    store = CheckpointStore(root, keep=2)
    steps = store.list_steps()
    assert 1 in steps
    for step in steps:
        restored, meta = store.restore(
            {"w": np.zeros(32, np.float32)} if step == 1
            else {"policy_state": np.zeros(4096, np.float32)}, step=step)
        assert meta["step"] == step


# ---------------------------------------------------------------------------
# trainer integration
# ---------------------------------------------------------------------------

def test_trainer_consumes_policy_checkpoint_decision():
    """A policy-mode ddp run routes the §4.4 post-fallback save through
    the engine: the decision lands in the audit trail and the trainer
    saves with reason="post-fallback" (its own store, real state)."""
    r = run_scenario(SCENARIOS["sender_nic_down"], workload="ddp",
                     policy="adaptive")
    assert r.policy == "adaptive"
    responses = [d[2] for d in r.decision_log]
    assert "checkpoint" in responses, r.decision_log
    assert r.fallbacks >= 1


# ---------------------------------------------------------------------------
# campaign determinism: byte-identical audit trails
# ---------------------------------------------------------------------------

def test_policy_matrix_deterministic_including_decisions():
    """Same seed, same matrix — byte-identical cells INCLUDING the
    decision logs and the fingerprints they fold into."""
    kw = dict(policies=("checkpoint", "adaptive"),
              scenario_names=("link_flap_train",),
              max_rounds=60, elems=1 << 10)
    m1 = run_policy_matrix(**kw)
    m2 = run_policy_matrix(**kw)
    assert m1 == m2
    cell = m1["adaptive"]["link_flap_train"]
    assert cell["decisions"] > 0
    assert cell["fingerprint"] == \
        m2["adaptive"]["link_flap_train"]["fingerprint"]


def test_policy_run_fingerprint_covers_decision_log():
    """Two runs of the same cell under DIFFERENT policies produce
    different fingerprints — the audit trail is part of the determinism
    contract, not a side channel."""
    kw = dict(workload="allreduce", seed=0, channels=2, max_rounds=60,
              elems=1 << 10)
    r_fixed = run_scenario(SCENARIOS["link_flap_train"],
                           policy="shift_fallback", **kw)
    r_adaptive = run_scenario(SCENARIOS["link_flap_train"],
                              policy="adaptive", **kw)
    assert r_fixed.policy != r_adaptive.policy
    assert r_fixed.fingerprint() != r_adaptive.fingerprint()


def test_policies_export_is_consistent():
    assert set(FIXED_POLICIES) < set(POLICIES)
    assert "adaptive" in POLICIES and "adaptive" not in FIXED_POLICIES
