"""Campaign-engine tests: the named scenario library runs deterministically
against ShiftLib workloads and every run passes the exactly-once,
zero-copy, notification-order, and bounded-fallback-latency invariants."""

import pytest

from repro.core.fabric import build_cluster, correlated_failure, flap_train
from repro.scenarios import (SCENARIOS, Campaign, FaultAction, Scenario,
                             run_scenario)

ALL_SCENARIOS = sorted(SCENARIOS)


# ---------------------------------------------------------------------------
# library shape
# ---------------------------------------------------------------------------

def test_library_names_the_required_scenarios():
    assert len(SCENARIOS) >= 10
    required = {"link_flap_train", "correlated_rail_failure",
                "failure_during_recovery", "simultaneous_bidirectional"}
    assert required <= set(SCENARIOS)


def test_scenario_spec_validates_kinds_and_times():
    with pytest.raises(ValueError):
        FaultAction(1e-3, "nuke_datacenter", "host0/mlx5_0")
    with pytest.raises(ValueError):
        FaultAction(-1.0, "nic_down", "host0/mlx5_0")
    with pytest.raises(ValueError):  # must come back up before next flap
        flap_train("host0/mlx5_0", start=0, count=2,
                   down_time=5e-3, period=4e-3)


# ---------------------------------------------------------------------------
# fabric fault hooks
# ---------------------------------------------------------------------------

def test_rail_selector_resolves_to_every_host():
    c = build_cluster(n_hosts=3, nics_per_host=2)
    gids = c.resolve_targets("rail:1")
    assert sorted(gids) == ["host0/mlx5_1", "host1/mlx5_1", "host2/mlx5_1"]


def test_fault_log_and_listeners_record_applied_faults():
    c = build_cluster(n_hosts=2, nics_per_host=2)
    seen = []
    c.add_fault_listener(lambda t, kind, gid: seen.append((kind, gid)))
    for t, kind, target in correlated_failure(["rail:0"], at=1e-3):
        c.schedule_fault(t, kind, target)
    c.sim.run(until=2e-3)
    assert seen == [("nic_down", "host0/mlx5_0"),
                    ("nic_down", "host1/mlx5_0")]
    assert [(k, g) for _, k, g in c.fault_log] == seen
    assert not c.nic_by_gid["host0/mlx5_0"].up


def test_unknown_fault_kind_rejected():
    c = build_cluster()
    with pytest.raises(ValueError):
        c.apply_fault("chaos", "host0/mlx5_0")


# ---------------------------------------------------------------------------
# the scenario matrix (pingpong workload: per-message delivery trace)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL_SCENARIOS)
def test_scenario_pingpong_invariants(name):
    sc = SCENARIOS[name]
    r = run_scenario(sc)
    assert r.ok, r.violations
    assert r.payload_bytes_held == 0           # zero-copy
    assert r.payload_mismatches == 0
    if sc.expect_masked:
        # exactly-once, in order, complete
        assert r.delivered == list(range(r.n_expected))
        assert not r.aborted and r.app_errors == 0
        if r.fault_log:
            # an empty log means every action no-opped on this topology
            # (dcn_* selectors on the single-pod pingpong cluster) — no
            # fault existed to bite, so the floor is waived
            assert r.fallbacks >= sc.min_fallbacks
    else:
        # boundary of fault tolerance: error propagated, never silent
        assert r.aborted and r.errors_propagated >= 1
        # the prefix that did arrive is still exactly-once and ordered
        assert r.delivered == sorted(set(r.delivered))


@pytest.mark.parametrize("name", ["sender_nic_down", "link_flap_train",
                                  "simultaneous_bidirectional",
                                  "failure_during_recovery"])
def test_scenario_determinism_same_seed_identical_events(name):
    r1 = run_scenario(SCENARIOS[name], seed=7)
    r2 = run_scenario(SCENARIOS[name], seed=7)
    assert r1.event_count == r2.event_count
    assert r1.fingerprint() == r2.fingerprint()


def test_different_seed_changes_payloads_not_correctness():
    r1 = run_scenario(SCENARIOS["sender_nic_down"], seed=1)
    r2 = run_scenario(SCENARIOS["sender_nic_down"], seed=2)
    assert r1.ok and r2.ok
    # same event structure is NOT required across seeds, but both deliver
    assert r1.delivered == r2.delivered == list(range(r1.n_expected))


# ---------------------------------------------------------------------------
# allreduce workload (payload-level exactly-once: sums must be exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sender_nic_down",
                                  "correlated_rail_failure",
                                  "failure_during_recovery"])
def test_scenario_allreduce_invariants(name):
    r = run_scenario(SCENARIOS[name], workload="allreduce", max_rounds=1500)
    assert r.ok, r.violations
    assert r.rounds > 0 and r.payload_mismatches == 0
    assert r.order_violations == 0 and r.duplicate_notifies == 0
    assert r.fallbacks >= SCENARIOS[name].min_fallbacks


def test_scenario_allreduce_unmaskable_aborts_loudly():
    r = run_scenario(SCENARIOS["double_rail_outage"], workload="allreduce",
                     max_rounds=1500)
    assert r.ok, r.violations
    assert r.aborted and r.errors_propagated >= 1
    assert r.payload_mismatches == 0   # completed rounds stayed correct


def test_scenario_allreduce_deterministic():
    r1 = run_scenario(SCENARIOS["sender_nic_down"], workload="allreduce",
                      max_rounds=400, seed=5)
    r2 = run_scenario(SCENARIOS["sender_nic_down"], workload="allreduce",
                      max_rounds=400, seed=5)
    assert r1.fingerprint() == r2.fingerprint()


# ---------------------------------------------------------------------------
# ddp workload (the paper's §5.2 experiment under scripted faults)
# ---------------------------------------------------------------------------

def test_scenario_ddp_masks_failure_and_finishes(tmp_path):
    r = run_scenario(SCENARIOS["sender_nic_down"], workload="ddp", steps=5)
    assert r.ok, r.violations
    assert r.completed and r.rounds == 5
    assert r.fallbacks >= 1


def test_rebase_preserves_outage_durations_and_gaps():
    """Anchor-only rebasing: the timeline START scales, each flap's
    authored 6ms outage and 9ms period survive verbatim (uniform
    scaling at scale=0.05 would shrink the outage to 0.3ms — under the
    ~3.2ms RC retry budget, so the fault would never bite)."""
    from repro.scenarios.engine import rebase_fault_times

    acts = SCENARIOS["link_flap_train"].actions
    scale = 0.05
    rebased = rebase_fault_times(acts, scale)
    by_time = sorted(rebased)
    # anchor (first down) moved to anchor*scale
    assert by_time[0][0] == pytest.approx(2e-3 * scale)
    # every inter-action delta is exactly as authored
    orig = sorted(a.at for a in acts)
    new = [t for t, *_ in by_time]
    for i in range(1, len(orig)):
        assert new[i] - new[i - 1] == pytest.approx(orig[i] - orig[i - 1])
    # in particular the first down->up outage is still the authored 6ms
    downs = [t for t, kind, *_ in by_time if kind == "link_down"]
    ups = [t for t, kind, *_ in by_time if kind == "link_up"]
    assert ups[0] - downs[0] == pytest.approx(6e-3)
    assert rebase_fault_times((), 0.5) == []


@pytest.mark.parametrize("workload", ["ddp", "ddp_bucketed"])
def test_scenario_ddp_flap_train_fault_bites(workload):
    """The previously-forbidden ddp x flap-train cells: anchor-only
    rebasing keeps the outage above the RC retry budget, so the flap
    forces a real fallback and the run still completes masked."""
    r = run_scenario(SCENARIOS["link_flap_train"], workload=workload)
    assert r.ok, r.violations
    assert r.completed and not r.aborted
    assert r.fallbacks >= 1


# ---------------------------------------------------------------------------
# campaign runner
# ---------------------------------------------------------------------------

def test_campaign_matrix_runs_and_reports():
    scs = [SCENARIOS["baseline_clean"], SCENARIOS["sender_nic_down"]]
    campaign = Campaign(scs, workloads=("pingpong", "allreduce"),
                        workload_kw={"allreduce": {"max_rounds": 300}})
    results = campaign.run()
    assert len(results) == 4
    assert all(r.ok for r in results), [r.violations for r in results]
    report = Campaign.report(results)
    assert "sender_nic_down" in report and "ok" in report


def test_campaign_rejects_unknown_workload():
    with pytest.raises(ValueError):
        Campaign([SCENARIOS["baseline_clean"]], workloads=("tpu_pod",))


def test_custom_scenario_composes_from_generators():
    from repro.scenarios import correlated, flap_train as sflap
    sc = Scenario(
        name="custom_compound",
        description="flap train then a correlated rail hit",
        actions=sflap("host1/mlx5_0", start=2e-3, count=2,
                      down_time=2e-3, period=6e-3)
        + correlated(["rail:0"], at=20e-3)
        + correlated(["rail:0"], at=45e-3, kind="nic_up"),
        min_fallbacks=2, expect_recovery=True)
    r = run_scenario(sc)
    assert r.ok, r.violations
    assert r.delivered == list(range(r.n_expected))
