"""Tier-1 enforcement of the pydocstyle-lite docstring gate: every
public module/class/function/method under ``repro.collectives`` and
``repro.core`` must carry a docstring (tools/check_docstrings.py)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

import check_docstrings


def test_collectives_and_core_fully_documented():
    problems = check_docstrings.check()
    assert not problems, "\n".join(problems)


def test_checker_flags_missing_docstrings(tmp_path):
    pkg = tmp_path / "pkg"
    pkg.mkdir()
    (pkg / "mod.py").write_text(
        "def documented():\n    \"\"\"ok\"\"\"\n\n"
        "def naked():\n    pass\n\n"
        "class C:\n    \"\"\"ok\"\"\"\n"
        "    def m(self):\n        pass\n"
        "    def _private(self):\n        pass\n")
    problems = check_docstrings.check(packages=("pkg",), root=tmp_path)
    assert any("undocumented module mod" in p for p in problems)
    assert any("undocumented function naked" in p for p in problems)
    assert any("undocumented method C.m" in p for p in problems)
    assert not any("_private" in p for p in problems)
