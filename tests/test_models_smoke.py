"""Per-arch smoke tests: REDUCED configs, one forward + train-grad step +
prefill/decode on CPU; asserts shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models import build_model

ALL_ARCHS = C.list_archs()


def make_batch(cfg, batch=2, seq=16, key=0):
    rng = np.random.RandomState(key)
    toks = rng.randint(0, cfg.vocab, size=(batch, seq + 1)).astype(np.int32)
    batch_d = {"tokens": jnp.asarray(toks)}
    if cfg.family == "vlm":
        batch_d["image_embeds"] = jnp.asarray(
            rng.randn(batch, cfg.n_image_tokens, cfg.d_model),
            dtype=jnp.float32)
    return batch_d


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_grad(arch):
    cfg = C.smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss NaN/inf"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves), \
        f"{arch}: NaN grads"
    logits = jax.jit(model.forward)(params, batch["tokens"][:, :-1],
                                    img_embeds=batch.get("image_embeds"))
    assert logits.shape == (2, 16, cfg.vocab)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_then_decode(arch):
    cfg = C.smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, batch=2, seq=8)
    toks = batch["tokens"][:, :-1]
    logits, cache = jax.jit(lambda p, t: model.prefill(
        p, t, img_embeds=batch.get("image_embeds"), max_len=12))(params, toks)
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    step = jax.jit(model.decode_step)
    for _ in range(3):
        logits, cache = step(params, cache, nxt)
        assert logits.shape == (2, 1, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    assert int(cache["len"]) == 8 + 3


@pytest.mark.parametrize("arch", ["gpt2-124m", "rwkv6-3b", "zamba2-1.2b",
                                  "kimi-k2-1t-a32b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits must match the parallel forward —
    validates cache correctness per family. (MoE: capacity dropping is
    batch-dependent by design, so use a no-drop capacity factor here.)"""
    overrides = {"capacity_factor": 8.0} if arch == "kimi-k2-1t-a32b" else {}
    cfg = C.smoke_config(arch, **overrides)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch = make_batch(cfg, batch=1, seq=10)
    toks = batch["tokens"][:, :-1]  # (1, 10)
    ref = np.asarray(jax.jit(model.forward)(
        params, toks, img_embeds=batch.get("image_embeds")),
        dtype=np.float32)
    # prefill on the first 5, decode the next 5 teacher-forced
    _, cache = jax.jit(lambda p, t: model.prefill(p, t, max_len=12))(
        params, toks[:, :5])
    step = jax.jit(model.decode_step)
    for i in range(5, 10):
        logits, cache = step(params, cache, toks[:, i:i + 1])
        got = np.asarray(logits, dtype=np.float32)[0, 0]
        np.testing.assert_allclose(got, ref[0, i], rtol=0.1, atol=0.15)


def test_param_count_formulas_roughly_match():
    for arch in ALL_ARCHS:
        cfg = C.smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(np.prod(l.shape) for l in
                     jax.tree_util.tree_leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.35, \
            f"{arch}: count formula off ({predicted} vs {actual})"


def test_full_config_param_counts():
    """The FULL configs match their published sizes (order of magnitude +)."""
    expect = {
        "starcoder2-15b": 15e9, "yi-6b": 6e9, "starcoder2-3b": 3e9,
        "deepseek-67b": 67e9, "rwkv6-3b": 3e9, "zamba2-1.2b": 1.2e9,
        "kimi-k2-1t-a32b": 1.0e12, "llama4-maverick-400b-a17b": 400e9,
        "musicgen-medium": 1.5e9, "llama-3.2-vision-90b": 90e9,
    }
    for arch, n in expect.items():
        cfg = C.get_config(arch)
        got = cfg.param_count()
        assert 0.5 * n < got < 2.1 * n, f"{arch}: {got:.3g} vs {n:.3g}"
    # MoE active params
    kimi = C.get_config("kimi-k2-1t-a32b")
    assert 15e9 < kimi.active_param_count() < 60e9
    mav = C.get_config("llama4-maverick-400b-a17b")
    assert 8e9 < mav.active_param_count() < 40e9
