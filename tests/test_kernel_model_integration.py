"""End-to-end kernel routing: a model with cfg.use_kernels=True must match
the pure-jnp path (interpret-mode Pallas on CPU; tiny config)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.models import build_model


def test_gpt2_smoke_kernels_match_jnp_path():
    cfg_j = C.smoke_config("gpt2-124m", dtype=jnp.float32)
    cfg_k = C.smoke_config("gpt2-124m", dtype=jnp.float32, use_kernels=True)
    mj, mk = build_model(cfg_j), build_model(cfg_k)
    params = mj.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg_j.vocab)
    lj = np.asarray(mj.forward(params, toks), np.float32)
    lk = np.asarray(mk.forward(params, toks), np.float32)
    np.testing.assert_allclose(lj, lk, rtol=5e-3, atol=5e-3)


def test_rwkv_smoke_kernels_match_jnp_path():
    cfg_j = C.smoke_config("rwkv6-3b", dtype=jnp.float32)
    cfg_k = C.smoke_config("rwkv6-3b", dtype=jnp.float32, use_kernels=True)
    mj, mk = build_model(cfg_j), build_model(cfg_k)
    params = mj.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg_j.vocab)
    lj = np.asarray(mj.forward(params, toks), np.float32)
    lk = np.asarray(mk.forward(params, toks), np.float32)
    np.testing.assert_allclose(lj, lk, rtol=5e-3, atol=5e-3)


def test_zamba_smoke_kernels_match_jnp_path():
    cfg_j = C.smoke_config("zamba2-1.2b", dtype=jnp.float32)
    cfg_k = C.smoke_config("zamba2-1.2b", dtype=jnp.float32, use_kernels=True)
    mj, mk = build_model(cfg_j), build_model(cfg_k)
    params = mj.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg_j.vocab)
    lj = np.asarray(mj.forward(params, toks), np.float32)
    lk = np.asarray(mk.forward(params, toks), np.float32)
    np.testing.assert_allclose(lj, lk, rtol=5e-3, atol=5e-3)
