"""Multi-rail channelized JCCL: striping, rail-aware failover, scheduler
resteering, bounded notify bookkeeping, and the new campaign workloads."""

import numpy as np
import pytest

from repro.collectives import build_world
from repro.core.shift import ShiftConfig, ShiftLib
from repro.scenarios import SCENARIOS, run_scenario


# ---------------------------------------------------------------------------
# rail-aware backup placement (ShiftConfig.backup_index policy)
# ---------------------------------------------------------------------------

def test_backup_placement_default_is_next_rail():
    cfg = ShiftConfig()
    assert cfg.backup_index(0, 2) == 1
    assert cfg.backup_index(0, 4) == 1


def test_backup_placement_prefers_spare_rails():
    # 2 data rails + 1 spare: both channels back up onto the spare, so
    # neither fails over onto the other channel's default rail
    cfg = ShiftConfig(data_rails=2)
    assert cfg.backup_index(0, 3) == 2
    assert cfg.backup_index(1, 3) == 2
    # 2 data rails + 2 spares: spread across the spares
    assert cfg.backup_index(0, 4) == 2
    assert cfg.backup_index(1, 4) == 3
    # no spares: mutual next-rail backup is the only option left
    assert cfg.backup_index(0, 2) == 1
    assert cfg.backup_index(1, 2) == 0


def test_backup_placement_overrides_win():
    cfg = ShiftConfig(data_rails=2, backup_overrides={0: 3})
    assert cfg.backup_index(0, 4) == 3
    assert cfg.backup_index(1, 4) == 3  # non-overridden falls to policy


def test_build_world_places_backups_on_spare_rail():
    cluster, libs, world = build_world(n_ranks=2, channels=2,
                                       nics_per_host=3)
    for ch in world.channels:
        for ep in ch.endpoints:
            assert ep.ctx.backup is not None
            assert ep.ctx.backup.nic.name == "mlx5_2"


def test_channels_cannot_exceed_rails():
    with pytest.raises(ValueError):
        build_world(n_ranks=2, channels=3, nics_per_host=2)


# ---------------------------------------------------------------------------
# striped collective correctness
# ---------------------------------------------------------------------------

def test_striped_allreduce_exact_multibucket():
    _, _, world = build_world(n_ranks=4, channels=2,
                              max_chunk_bytes=4096)
    n = 4096 * 5 + 37  # several buckets + ragged tail
    arrays = [np.arange(n, dtype=np.int64) * (r + 1) for r in range(4)]
    expect = sum(a.copy() for a in arrays)
    world.allreduce(arrays)
    for a in arrays:
        np.testing.assert_array_equal(a, expect)


def test_striped_allreduce_uses_both_channels():
    _, _, world = build_world(n_ranks=2, channels=2,
                              max_chunk_bytes=4096)
    arrays = [np.ones(4096 * 8, dtype=np.float32) * (r + 1)
              for r in range(2)]
    world.allreduce(arrays)
    np.testing.assert_allclose(arrays[0], 3.0)
    assigned = world.scheduler.assigned
    assert all(a > 0 for a in assigned), assigned
    assert world.scheduler.resteered == 0  # clean run: homes honoured
    delivered = [ch.chunks_delivered for ch in world.channels]
    assert delivered == assigned


def test_striped_other_collectives_exact():
    _, _, world = build_world(n_ranks=4, channels=2,
                              max_chunk_bytes=1 << 14)
    shards = [np.full(17 + r, r, dtype=np.float32) for r in range(4)]
    full = world.all_gather(shards)
    expect = np.concatenate(shards)
    for f in full:
        np.testing.assert_array_equal(f, expect)

    msg = np.arange(50000, dtype=np.float32)  # several pipeline chunks
    outs = world.broadcast(msg, root=2)
    for o in outs:
        np.testing.assert_array_equal(o, msg)

    mats = [np.arange(4 * 8, dtype=np.int64).reshape(4, 8) + 100 * r
            for r in range(4)]
    outs = world.all_to_all(mats)
    for j in range(4):
        for i in range(4):
            np.testing.assert_array_equal(outs[j][i], mats[i][j])

    arrays = [np.arange(64, dtype=np.int64) for _ in range(4)]
    owned = world.reduce_scatter(arrays)
    per = 16
    flat = np.arange(64, dtype=np.int64) * 4
    for r in range(4):
        own = (r + 1) % 4
        np.testing.assert_array_equal(owned[r],
                                      flat[own * per:(own + 1) * per])


def test_striped_allreduce_exact_on_legacy_datapath():
    _, _, world = build_world(n_ranks=2, channels=2,
                              max_chunk_bytes=4096, fast=False)
    arrays = [np.ones(4096 * 4, dtype=np.float64) * (r + 1)
              for r in range(2)]
    world.allreduce(arrays)
    np.testing.assert_allclose(arrays[0], 3.0)
    assert all(a > 0 for a in world.scheduler.assigned)


# ---------------------------------------------------------------------------
# virtual-time bandwidth: striping must roughly double busbw on 2 rails
# ---------------------------------------------------------------------------

def test_striped_stream_busbw_scales():
    size, chunks = 1 << 15, 64

    def busbw(channels):
        cluster, _, world = build_world(n_ranks=2, channels=channels,
                                        max_chunk_bytes=size)
        payload = np.ones(size, dtype=np.uint8)
        t0 = cluster.sim.now
        for i in range(chunks):
            world.send(0, 1, payload, tag=i)
        while (sum(ch.chunks_delivered for ch in world.channels) < chunks
               and cluster.sim.step()):
            pass
        return chunks * size / (cluster.sim.now - t0)

    ratio = busbw(2) / busbw(1)
    assert ratio >= 1.8, f"2-rail striping only {ratio:.2f}x"


def test_rail_byte_accounting_splits_across_rails():
    cluster, _, world = build_world(n_ranks=2, channels=2,
                                    max_chunk_bytes=1 << 14)
    arrays = [np.ones((1 << 14), dtype=np.float32) * (r + 1)
              for r in range(2)]
    world.allreduce(arrays)
    rails = cluster.rail_bytes()
    assert rails[0]["delivered_bytes"] > 0
    assert rails[1]["delivered_bytes"] > 0
    assert rails[0]["tx_bytes"] > 0 and rails[1]["tx_bytes"] > 0


# ---------------------------------------------------------------------------
# rail-aware failover + scheduler resteering
# ---------------------------------------------------------------------------

def test_rail_kill_mid_striped_allreduce_masked_and_resteered():
    cluster, libs, world = build_world(n_ranks=2, channels=2,
                                       max_chunk_bytes=4096,
                                       probe_interval=5e-3)
    n = 4096 * 16
    # one warm round so both channels are demonstrably in use
    warm = [np.ones(n, dtype=np.float64) for _ in range(2)]
    world.allreduce(warm)
    pre_assigned = list(world.scheduler.assigned)
    assert all(a > 0 for a in pre_assigned)
    # kill channel 0's rail on host0 mid-collective
    cluster.sim.at(cluster.sim.now + 1e-4, cluster.fail_nic, "host0/mlx5_0")
    arrays = [np.full(n, float(r + 1), dtype=np.float64) for r in range(2)]
    world.allreduce(arrays)
    for a in arrays:
        np.testing.assert_allclose(a, 3.0)  # numerics exact
    assert any(isinstance(lib, ShiftLib) and lib.stats.fallbacks > 0
               for lib in libs)             # the fault actually bit
    # several more rounds while rail 0 is dark: the scheduler must move
    # chunk homes onto the surviving channel
    for _ in range(4):
        arrays = [np.full(n, float(r + 1), dtype=np.float64)
                  for r in range(2)]
        world.allreduce(arrays)
        np.testing.assert_allclose(arrays[0], 3.0)
    assert world.scheduler.resteered > 0
    post_assigned = world.scheduler.assigned
    moved = [post_assigned[c] - pre_assigned[c] for c in range(2)]
    assert moved[1] > moved[0], (
        f"surviving channel should carry the resteered chunks: {moved}")


def test_scheduler_rebalances_after_recovery():
    cluster, libs, world = build_world(n_ranks=2, channels=2,
                                       max_chunk_bytes=4096,
                                       probe_interval=2e-3)
    n = 4096 * 8
    cluster.fail_nic("host0/mlx5_0")
    arrays = [np.ones(n, dtype=np.float64) for _ in range(2)]
    world.allreduce(arrays)          # forces fallback + resteer
    assert world.scheduler.resteered > 0
    cluster.recover_nic("host0/mlx5_0")
    # keep signaled traffic flowing so the probe + recovery fence land
    for _ in range(8):
        world.allreduce([np.ones(4096, dtype=np.float64)
                         for _ in range(2)])
        cluster.sim.run(until=cluster.sim.now + 2e-3)
    assert any(isinstance(lib, ShiftLib) and lib.stats.recoveries > 0
               for lib in libs)
    pre = list(world.scheduler.assigned)
    world.allreduce([np.ones(n, dtype=np.float64) for _ in range(2)])
    post = world.scheduler.assigned
    # after recovery, channel 0 carries home traffic again
    assert post[0] > pre[0]


def test_backup_nic_flap_then_default_failure_still_masked():
    """A backup-rail outage (which flushes the control QP living there)
    followed, after recovery, by a default-rail failure: SHIFT must
    revive the control path and still mask (regression test for the
    lazy ctrl-QP repair)."""
    cluster, libs, world = build_world(n_ranks=2, channels=1,
                                       max_chunk_bytes=4096,
                                       probe_interval=2e-3)
    n = 4096 * 8
    # blip the backup rail while traffic rides the default
    cluster.fail_nic("host0/mlx5_1")
    world.allreduce([np.ones(n, dtype=np.float64) for _ in range(2)])
    cluster.recover_nic("host0/mlx5_1")
    cluster.sim.run(until=cluster.sim.now + 5e-3)
    # now kill the default: fallback needs the (previously flushed) ctrl QP
    cluster.sim.at(cluster.sim.now + 1e-4, cluster.fail_nic, "host0/mlx5_0")
    arrays = [np.full(n, float(r + 1), dtype=np.float64) for r in range(2)]
    world.allreduce(arrays)
    np.testing.assert_allclose(arrays[0], 3.0)
    assert any(isinstance(lib, ShiftLib) and lib.stats.fallbacks > 0
               for lib in libs)
    assert all(lib.stats.errors_propagated == 0 for lib in libs
               if isinstance(lib, ShiftLib))


# ---------------------------------------------------------------------------
# bounded notify bookkeeping (the seen_notifies leak fix)
# ---------------------------------------------------------------------------

def test_notify_bookkeeping_stays_bounded():
    _, _, world = build_world(n_ranks=2, channels=2,
                              max_chunk_bytes=4096)
    for _ in range(20):
        arrays = [np.ones(4096 * 4, dtype=np.float32) for _ in range(2)]
        world.allreduce(arrays)
    # thousands of messages later, per-peer bookkeeping holds ZERO
    # retained imm values in a clean run (the old seen-set grew by one
    # entry per message forever)
    for ch in world.channels:
        for ep in ch.endpoints:
            for peer, missing in ep.missing_notifies.items():
                assert len(missing) == 0
                assert ep.recv_seq[peer] > 0  # traffic actually flowed


# ---------------------------------------------------------------------------
# campaign integration: multirail scenarios + new workloads
# ---------------------------------------------------------------------------

def test_library_names_the_multirail_scenarios():
    required = {"rail_kill_striped", "staggered_dual_rail_faults",
                "rail_recovery_rebalance"}
    assert required <= set(SCENARIOS)
    for name in required:
        assert SCENARIOS[name].min_resteers >= 1


@pytest.mark.parametrize("name", ["rail_kill_striped",
                                  "staggered_dual_rail_faults",
                                  "rail_recovery_rebalance"])
def test_multirail_scenarios_striped_allreduce(name):
    r = run_scenario(SCENARIOS[name], workload="allreduce",
                     max_rounds=1200)
    assert r.ok, r.violations
    assert r.payload_mismatches == 0
    assert r.fallbacks >= SCENARIOS[name].min_fallbacks
    assert r.resteered_chunks >= 1
    assert r.channel_stats is not None and len(r.channel_stats) == 2
    for c in r.channel_stats:
        assert c["chunks_assigned"] == c["chunks_delivered"]


def test_multirail_scenario_deterministic():
    r1 = run_scenario(SCENARIOS["rail_kill_striped"], workload="allreduce",
                      max_rounds=400, seed=3)
    r2 = run_scenario(SCENARIOS["rail_kill_striped"], workload="allreduce",
                      max_rounds=400, seed=3)
    assert r1.fingerprint() == r2.fingerprint()


@pytest.mark.parametrize("workload", ["broadcast", "all_to_all"])
@pytest.mark.parametrize("name", ["baseline_clean", "sender_nic_down",
                                  "failure_during_recovery"])
def test_new_workloads_under_faults(name, workload):
    r = run_scenario(SCENARIOS[name], workload=workload, max_rounds=800)
    assert r.ok, r.violations
    assert r.rounds > 0 and r.payload_mismatches == 0
    assert r.fallbacks >= SCENARIOS[name].min_fallbacks


@pytest.mark.parametrize("workload", ["broadcast", "all_to_all"])
def test_new_workloads_unmaskable_aborts_loudly(workload):
    r = run_scenario(SCENARIOS["double_rail_outage"], workload=workload,
                     max_rounds=800)
    assert r.ok, r.violations
    assert r.aborted and r.errors_propagated >= 1
    assert r.payload_mismatches == 0
