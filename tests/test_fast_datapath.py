"""Fast-datapath equivalence + simulator compaction tests (DESIGN.md §5).

The coalescing zero-copy datapath (``Cluster.fast_datapath=True``) must
be byte-identical to the legacy per-WQE copying path for every opcode,
and the overhauled simulator must keep cancelled events from leaking.
"""

import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.core import verbs as V
from repro.core.fabric import Simulator, build_cluster
from repro.scenarios import SCENARIOS
from repro.scenarios.engine import make_pair, run_scenario


# ---------------------------------------------------------------------------
# simulator: tuple records, call(), lazy-deletion compaction
# ---------------------------------------------------------------------------


def test_simulator_call_and_schedule_interleave_in_order():
    sim = Simulator()
    out = []
    sim.schedule(2e-3, out.append, "b")
    sim.call(1e-3, out.append, "a")
    sim.call(3e-3, out.append, "c")
    sim.run_until_idle()
    assert out == ["a", "b", "c"]
    assert sim._executed == 3


def test_cancelled_events_do_not_fire_and_heap_compacts():
    sim = Simulator()
    out = []
    evs = [sim.schedule(1.0 + i * 1e-6, out.append, i) for i in range(500)]
    for ev in evs[:499]:
        ev.cancel()
    # compaction triggers once dead events exceed half the heap
    sim.schedule(2.0, out.append, "tail")
    assert len(sim._heap) < 500, "cancel leak: dead events linger in heap"
    assert sim._compactions >= 1
    sim.run_until_idle()
    assert out == [499, "tail"]


def test_cancel_after_fire_is_a_noop():
    """Cancelling an event that already executed must not inflate the
    dead-event count (which would trigger no-op compactions)."""
    sim = Simulator()
    ev = sim.schedule(1e-3, lambda: None)
    sim.run_until_idle()
    ev.cancel()
    assert sim._dead == 0 and not ev.cancelled


def test_peek_time_skips_cancelled():
    sim = Simulator()
    ev = sim.schedule(1.0, lambda: None)
    sim.schedule(2.0, lambda: None)
    ev.cancel()
    assert sim.peek_time() == pytest.approx(2.0)


def test_compaction_during_run_keeps_future_events():
    """Regression: compaction must rebuild the heap IN PLACE — run()
    holds a reference to the heap list across events."""
    sim = Simulator()
    fired = []

    def schedule_more():
        # force a compaction while run() is mid-loop...
        evs = [sim.schedule(5.0, fired.append, -1) for _ in range(200)]
        for ev in evs:
            ev.cancel()
        sim.schedule(1e-3, fired.append, "later")  # triggers compaction

    sim.schedule(0.0, schedule_more)
    sim.run_until_idle()
    # ...and the event scheduled after compaction must still fire
    assert fired == ["later"]


# ---------------------------------------------------------------------------
# byte-identical delivery: fast vs legacy across opcodes
# ---------------------------------------------------------------------------


def _run_script(fast, script):
    """Execute a list of (op, size, src_off, dst_off) transfers on a fresh
    standard pair; returns (dst bytes, src bytes, wc stream, recv stream)."""
    c, a, b = make_pair("standard", fast=fast,
                        endpoint_kw={"buf_size": 1 << 16})
    rng = np.random.RandomState(1234)
    a.buf[:] = rng.randint(0, 256, a.buf.size, dtype=np.uint8)
    b.buf[:] = rng.randint(0, 256, b.buf.size, dtype=np.uint8)
    wrs = []
    for i, (op, size, s_off, d_off) in enumerate(script):
        if op in ("SEND", "WRITE_IMM"):
            b.lib.post_recv(b.qp, V.RecvWR(
                wr_id=1000 + i, sge=V.SGE(b.mr.addr + d_off, size,
                                          b.mr.lkey)))
        if op in ("FETCH_ADD", "CMP_SWAP"):
            wrs.append(V.SendWR(
                wr_id=i, opcode=V.Opcode[op],
                sge=V.SGE(a.mr.addr + s_off, 8, a.mr.lkey),
                remote_addr=b.mr.addr + (d_off & ~7), rkey=b.mr.rkey,
                compare_add=3, swap=7))
        else:
            wrs.append(V.SendWR(
                wr_id=i, opcode=V.Opcode[op],
                sge=V.SGE(a.mr.addr + s_off, size, a.mr.lkey),
                remote_addr=b.mr.addr + d_off, rkey=b.mr.rkey,
                imm_data=i))
    # mix posting styles: chain the first half, post the rest singly
    half = len(wrs) // 2
    if half:
        a.lib.post_send_chain(a.qp, wrs[:half])
    for wr in wrs[half:]:
        a.lib.post_send(a.qp, wr)
    c.sim.run(until=c.sim.now + 1.0)
    send_wcs = a.poll()
    recv_wcs = b.poll()
    return (bytes(b.buf.tobytes()), bytes(a.buf.tobytes()),
            [(w.wr_id, w.status, w.opcode) for w in send_wcs],
            [(w.wr_id, w.status, w.opcode, w.imm_data, w.byte_len)
             for w in recv_wcs])


OPS = ["WRITE", "WRITE_IMM", "SEND", "READ", "FETCH_ADD", "CMP_SWAP"]


def test_all_opcodes_byte_identical_fast_vs_legacy():
    script = []
    for i, op in enumerate(OPS * 4):
        size = 64 + 32 * i
        script.append((op, size, (i * 256) % 8192, (i * 512) % 16384))
    slow = _run_script(False, script)
    fast = _run_script(True, script)
    assert fast[0] == slow[0], "destination memory differs"
    assert fast[1] == slow[1], "source memory differs (READ/atomic returns)"
    assert fast[2] == slow[2], "send WC stream differs"
    assert fast[3] == slow[3], "recv WC stream differs"


@given(st.lists(st.tuples(st.sampled_from(OPS),
                          st.integers(min_value=8, max_value=2048),
                          st.integers(min_value=0, max_value=50),
                          st.integers(min_value=0, max_value=50)),
                min_size=1, max_size=24))
@settings(max_examples=20, deadline=None)
def test_property_fast_vs_legacy_byte_identical(raw):
    script = [(op, size, s * 128, d * 128) for op, size, s, d in raw]
    slow = _run_script(False, script)
    fast = _run_script(True, script)
    assert fast == slow


def test_chain_post_equals_single_posts():
    """A posted WR chain must deliver exactly like sequential posts."""
    script = [("WRITE", 512, i * 512, i * 512) for i in range(12)]
    c, a, b = make_pair("standard", fast=True,
                        endpoint_kw={"buf_size": 1 << 16})
    a.buf[:] = 7
    wrs = [V.SendWR(wr_id=i, opcode=V.Opcode.WRITE,
                    sge=V.SGE(a.mr.addr + s, n, a.mr.lkey),
                    remote_addr=b.mr.addr + d, rkey=b.mr.rkey)
           for i, (_, n, s, d) in enumerate(script)]
    a.lib.post_send_chain(a.qp, wrs)
    c.sim.run_until_idle()
    wcs = a.poll()
    assert [w.wr_id for w in wcs] == list(range(12))
    assert all(w.status is V.WCStatus.SUCCESS for w in wcs)
    assert (b.buf[:12 * 512] == 7).all()


# ---------------------------------------------------------------------------
# zero-copy semantics
# ---------------------------------------------------------------------------


def test_ro_view_is_read_only():
    c = build_cluster()
    ctx = V.ibv_open_device(c, "host0", "mlx5_0")
    pd = V.ibv_alloc_pd(ctx)
    buf = np.zeros(4096, dtype=np.uint8)
    mr = V.ibv_reg_mr(pd, buf)
    view = mr.ro_view(mr.addr, 128)
    with pytest.raises(ValueError):
        view[0] = 1
    # the writable path still works
    mr.slice(mr.addr, 128)[0] = 9
    assert view[0] == 9  # same memory, zero copies


def test_sq_ring_is_bounded():
    """The send queue is a true ring: memory stays O(cap) no matter how
    many WRs stream through it (O(1) ring-index bookkeeping)."""
    c, a, b = make_pair("standard", fast=True,
                        endpoint_kw={"buf_size": 1 << 16})
    cap = a.qp.cap.max_send_wr
    n = cap * 2 + 37
    for i in range(n):
        V.ibv_post_send(a.qp, V.SendWR(
            wr_id=i, opcode=V.Opcode.WRITE,
            sge=V.SGE(a.mr.addr, 64, a.mr.lkey),
            remote_addr=b.mr.addr, rkey=b.mr.rkey))
        if i % 64 == 0:
            c.sim.run(until=c.sim.now + 1e-3)
    c.sim.run_until_idle()
    assert len(a.qp.sq) <= cap
    assert a.qp.sq_tail == n
    wcs = a.poll(n + 1)
    assert len(wcs) == n and all(not w.is_error for w in wcs)


def test_backup_failure_with_only_unsignaled_outstanding_propagates():
    """Unsignaled sends are not in wqe_map; a backup-NIC death while only
    unsignaled WRs are outstanding must still reach _propagate_errors
    (regression: the flushed error WCs were silently swallowed)."""
    from repro.core.shift import SendState

    c, a, b = make_pair("shift", probe_interval=50e-3)
    # in-flight signaled traffic, then kill the default NIC -> fallback
    a.lib.post_send(a.qp, V.SendWR(
        wr_id=99, opcode=V.Opcode.WRITE,
        sge=V.SGE(a.mr.addr, 4096, a.mr.lkey),
        remote_addr=b.mr.addr, rkey=b.mr.rkey))
    c.fail_nic("host0/mlx5_0")
    c.sim.run(until=c.sim.now + 5e-3)
    assert a.lib.stats.fallbacks >= 1
    # post ONLY unsignaled writes (never mapped in wqe_map)...
    for i in range(4):
        a.lib.post_send(a.qp, V.SendWR(
            wr_id=i, opcode=V.Opcode.WRITE,
            sge=V.SGE(a.mr.addr, 4096, a.mr.lkey),
            remote_addr=b.mr.addr, rkey=b.mr.rkey, send_flags=0))
    # ...then cut the backup LINK mid-flight (NIC stays up, so the idle
    # control QP raises no error of its own): the data WQEs exhaust the
    # RC retry budget and their flush WCs are the ONLY failure signal
    c.fail_link("host0/mlx5_1")
    c.sim.run(until=c.sim.now + 50e-3)
    assert a.lib.stats.errors_propagated >= 1
    assert a.qp.send_state is SendState.FAILED


# ---------------------------------------------------------------------------
# campaign invariants in fast mode (all 14 scenarios)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_fast_mode_campaign_invariants(name):
    """Zero-copy / exactly-once / ordering invariants must stay green on
    the coalescing datapath with burst posting for every scenario."""
    r = run_scenario(SCENARIOS[name], fast=True, burst=8)
    assert r.ok, r.violations


def test_fast_and_legacy_campaign_delivery_traces_match():
    """Same scenario, same seed: the delivered-notification trace is
    identical across datapaths (timing may differ, content may not)."""
    for name in ("baseline_clean", "sender_nic_down", "nic_down_permanent"):
        slow = run_scenario(SCENARIOS[name], fast=False, burst=1)
        fast = run_scenario(SCENARIOS[name], fast=True, burst=8)
        assert slow.ok and fast.ok
        assert fast.delivered == slow.delivered
        assert fast.payload_mismatches == slow.payload_mismatches == 0
