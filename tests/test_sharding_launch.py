"""Launch machinery on a tiny (1,1) mesh: sharding-rule construction and
train/prefill/decode lowering for each family (the 512-device production
sweep runs via repro.launch.dryrun; this keeps the machinery covered by
the fast suite)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models import build_model
from repro.launch import sharding as SH
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.optim import AdamWConfig, adamw_init

FAMILY_ARCHS = ["gpt2-124m", "kimi-k2-1t-a32b", "rwkv6-3b", "zamba2-1.2b",
                "llama-3.2-vision-90b"]


def _setup(arch):
    cfg = C.smoke_config(arch)
    model = build_model(cfg)
    mesh = make_debug_mesh(1, 1)
    params_sds = jax.eval_shape(lambda k: model.init(k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = SH.param_specs(cfg, params_sds, mesh)
    return cfg, model, mesh, params_sds, pspecs


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_param_specs_cover_tree(arch):
    cfg, model, mesh, params_sds, pspecs = _setup(arch)
    n_leaves = len(jax.tree_util.tree_leaves(params_sds))
    n_specs = len(jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    assert n_specs == n_leaves


@pytest.mark.parametrize("arch", ["gpt2-124m", "rwkv6-3b"])
def test_train_step_lowers_on_mesh(arch):
    cfg, model, mesh, params_sds, pspecs = _setup(arch)
    p_shard = SH.to_named(pspecs, mesh)
    opt_cfg = AdamWConfig()
    opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
    ospecs = SH.opt_specs(cfg, opt_sds, pspecs, mesh)
    o_shard = SH.to_named(ospecs, mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 17), jnp.int32)}
    bspecs = SH.batch_specs(cfg, mesh, 2)
    b_shard = SH.to_named({"tokens": bspecs["tokens"]}, mesh)
    with mesh:
        step = make_train_step(model, opt_cfg)
        lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                          out_shardings=(p_shard, o_shard, None)).lower(
            params_sds, opt_sds, batch)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None


@pytest.mark.parametrize("arch", ["gpt2-124m", "zamba2-1.2b"])
def test_decode_step_lowers_with_cache_specs(arch):
    cfg, model, mesh, params_sds, pspecs = _setup(arch)
    p_shard = SH.to_named(pspecs, mesh)
    cache_sds = jax.eval_shape(lambda: model.init_cache(2, 32))
    cspecs = SH.cache_specs(cfg, cache_sds, mesh, 2)
    c_shard = SH.to_named(cspecs, mesh)
    toks = jax.ShapeDtypeStruct((2, 1), jnp.int32)
    with mesh:
        step = make_decode_step(model)
        lowered = jax.jit(step, in_shardings=(p_shard, c_shard, None),
                          donate_argnums=(1,)).lower(
            params_sds, cache_sds, toks)
        assert lowered.compile() is not None


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[2048,512]{1,0} all-gather(bf16[128,512]{1,0} %p), dims={0}
  %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%sum
  %rs = f32[64,8]{1,0} reduce-scatter(f32[512,8]{1,0} %y), dims={0}
  %other = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 128 * 512 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["reduce-scatter"] == 512 * 8 * 4
    assert out["all-to-all"] == 0


def test_serve_engine_generates():
    from repro.serving import ServeEngine
    cfg = C.smoke_config("gpt2-124m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_len=32)
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab, size=(2, 8)).astype(np.int32)
    out = eng.generate(prompts, n_tokens=6)
    assert out.shape == (2, 14)
    np.testing.assert_array_equal(out[:, :8], prompts)
