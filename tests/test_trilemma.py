"""Machine-checked counterexamples for the RDMA Failover Trilemma
(§3.1 / Appendix C) + hypothesis property tests over the simulator."""

import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.core import trilemma as T
from repro.core import verbs as V
from repro.core import shift as S
from repro.core.fabric import build_cluster
from repro.core.protocols import (FailoverClass, LLChannel, PROTOCOL_CLASS,
                                  Protocol, classify_wqe_set)


# ---------------------------------------------------------------------------
# Lemma 3.1: indistinguishability
# ---------------------------------------------------------------------------

def test_sender_views_identical():
    t1, t2 = T.trace_packet_lost(), T.trace_ack_lost()
    assert T.sender_view(t1) == T.sender_view(t2)
    assert t1 != t2  # yet the traces conflict


def test_fixed_decisions_violate_one_property():
    assert T.decision_violates(lambda view: False) == "liveness"
    assert T.decision_violates(lambda view: True) == "safety"


@given(st.integers(min_value=0, max_value=2 ** 16 - 1))
@settings(max_examples=64, deadline=None)
def test_any_deterministic_decision_function_fails(seed):
    """Theorem 3.3, property form: EVERY deterministic decision function of
    the sender view (here: an arbitrary hash-indexed boolean function)
    violates liveness or safety."""

    def decide(view):
        return bool((hash(view) ^ seed) & 1)

    assert T.decision_violates(decide) in ("liveness", "safety")


# ---------------------------------------------------------------------------
# Lemma 3.2: non-idempotency
# ---------------------------------------------------------------------------

def test_fadd_non_idempotent():
    assert T.fadd_non_idempotent()


@given(st.integers(min_value=1, max_value=10 ** 6))
@settings(max_examples=32, deadline=None)
def test_fadd_non_idempotent_any_delta(delta):
    assert T.fadd_non_idempotent(delta=delta)


def test_cas_double_success_aba():
    assert T.cas_double_success()


def test_two_sided_send_consumes_twice():
    assert T.send_non_idempotent()


def test_ll_write_after_reuse_corrupts():
    corrupted, observed = T.ll_write_after_reuse()
    assert corrupted and observed == T.V1  # app reads stale data as fresh


# ---------------------------------------------------------------------------
# Theorem 3.4: consensus barrier
# ---------------------------------------------------------------------------

def test_rw_registers_cannot_build_sticky_register():
    decided = T.rw_register_consensus_attempt()
    # exhaustive interleaving finds at least two conflicting winners
    assert "ghost" in decided and "backup" in decided


# ---------------------------------------------------------------------------
# Protocol classification (§3.2 Table 1)
# ---------------------------------------------------------------------------

def test_protocol_table():
    assert PROTOCOL_CLASS[Protocol.NCCL_SIMPLE] is FailoverClass.SAFE
    assert PROTOCOL_CLASS[Protocol.NVSHMEM_ATOMIC] is FailoverClass.UNSAFE_ATOMIC
    assert PROTOCOL_CLASS[Protocol.MSCCLPP_ATOMIC] is FailoverClass.UNSAFE_ATOMIC
    assert PROTOCOL_CLASS[Protocol.NCCL_LL] is FailoverClass.UNSAFE_PACKED
    assert PROTOCOL_CLASS[Protocol.NCCL_LL128] is FailoverClass.UNSAFE_PACKED


def test_classify_wqe_set_detects_atomics():
    class W:
        def __init__(self, op):
            self.opcode = op
    assert classify_wqe_set([W(V.Opcode.WRITE)]) is FailoverClass.SAFE
    assert classify_wqe_set(
        [W(V.Opcode.WRITE), W(V.Opcode.FETCH_ADD)]) is FailoverClass.UNSAFE_ATOMIC


# ---------------------------------------------------------------------------
# Property test over the real simulator: SHIFT preserves the invariants of
# §3.2 under ARBITRARY failure timing (hypothesis chooses when the NIC dies
# and when it recovers).
# ---------------------------------------------------------------------------

def _run_simple_stream(fail_at, recover_at, n_msgs=24, size=4096,
                       kill="host0/mlx5_0"):
    from test_shift import make_shift_pair, simple_step, drain  # noqa
    V.reset_registries()
    c, a, b = make_shift_pair()
    recv_wcs, send_wcs = [], []
    next_seq = [0]

    def pump():
        if next_seq[0] < n_msgs:
            simple_step(a, b, next_seq[0], size)
            next_seq[0] += 1
            c.sim.schedule(120e-6, pump)
        drain(b, recv_wcs)
        drain(a, send_wcs)

    pump()
    t0 = c.sim.now
    c.sim.at(t0 + fail_at, c.fail_nic, kill)
    c.sim.at(t0 + fail_at + recover_at, c.recover_nic, kill)
    c.sim.run(until=t0 + 1.5)
    drain(b, recv_wcs)
    drain(a, send_wcs)
    return a, b, send_wcs, recv_wcs


@given(fail_at=st.floats(min_value=1e-5, max_value=4e-3),
       recover_at=st.floats(min_value=1e-4, max_value=60e-3),
       kill=st.sampled_from(["host0/mlx5_0", "host1/mlx5_0"]))
@settings(max_examples=25, deadline=None)
def test_notification_exactly_once_in_order_any_timing(fail_at, recover_at,
                                                       kill):
    a, b, send_wcs, recv_wcs = _run_simple_stream(fail_at, recover_at,
                                                  kill=kill)
    imms = [w.imm_data for w in recv_wcs
            if w.opcode is V.WCOpcode.RECV_RDMA_WITH_IMM and not w.is_error]
    assert imms == list(range(24)), f"timing ({fail_at},{recover_at}): {imms}"
    ok = [w for w in send_wcs if not w.is_error]
    assert len(ok) == 24


# ---------------------------------------------------------------------------
# Empirical trilemma: NAIVE failover (retransmit everything outstanding)
# CAN corrupt LL-style packed traffic — the corruption the paper proves.
# ---------------------------------------------------------------------------

def test_naive_ll_failover_corrupts_on_simulator():
    """Reproduce Lemma C.5 on the live simulator: an LL slot is consumed and
    reused by the receiver app; a naive cross-NIC retransmission of the
    outstanding packed write silently clobbers the new value."""
    c = build_cluster(n_hosts=2, nics_per_host=2)
    lib_a, lib_b = S.StandardLib(c, "host0"), S.StandardLib(c, "host1")
    from test_shift import Endpoint  # noqa
    a, b = Endpoint(lib_a), Endpoint(lib_b)
    lib_a.connect(a.qp, *lib_b.route_of(b.qp))
    lib_b.connect(b.qp, *lib_a.route_of(a.qp))
    ll = LLChannel(b.mr)

    # sender writes packed (data=V1, seq=1) into slot 0
    a.buf[:8] = np.frombuffer(LLChannel.pack(77, 1), dtype=np.uint8)
    a.lib.post_send(a.qp, V.SendWR(
        wr_id=1, opcode=V.Opcode.WRITE, sge=V.SGE(a.mr.addr, 8, a.mr.lkey),
        remote_addr=b.mr.addr, rkey=b.mr.rkey))
    # ACK loss window: drop the sender-side return path right after delivery
    lat = c.path_latency(c.nic_by_gid["host0/mlx5_0"],
                         c.nic_by_gid["host1/mlx5_0"])
    down = V.PER_MESSAGE_OVERHEAD + 8 / 12.5e9 + lat + 1e-7
    c.sim.at(c.sim.now + down, c.fail_nic, "host0/mlx5_0")
    c.sim.run(until=c.sim.now + 0.1)

    # receiver consumed the data and reused the slot (flag recycled)
    assert ll.poll_slot(0, 1) == 77
    ll.reuse_slot(0, data=55, seq=1)

    # NAIVE failover: copy the outstanding WQE to the backup NIC and resend
    ctx_a2 = V.ibv_open_device(c, "host0", "mlx5_1")
    ctx_b2 = V.ibv_open_device(c, "host1", "mlx5_1")
    pd_a2, pd_b2 = V.ibv_alloc_pd(ctx_a2), V.ibv_alloc_pd(ctx_b2)
    mr_a2 = V.ibv_reg_mr(pd_a2, a.buf, addr=a.mr.addr)
    mr_b2 = V.ibv_reg_mr(pd_b2, b.buf, addr=b.mr.addr)
    cq2a = V.ibv_create_cq(ctx_a2, 64)
    cq2b = V.ibv_create_cq(ctx_b2, 64)
    qp2a = V.ibv_create_qp(pd_a2, V.QPInitAttr(send_cq=cq2a, recv_cq=cq2a))
    qp2b = V.ibv_create_qp(pd_b2, V.QPInitAttr(send_cq=cq2b, recv_cq=cq2b))
    V.connect_qps(qp2a, qp2b)
    wqe = a.qp.sq[0]
    wr = wqe.to_wr()
    wr.sge = V.SGE(mr_a2.addr, 8, mr_a2.lkey)
    wr.rkey = mr_b2.rkey
    V.ibv_post_send(qp2a, wr)
    c.sim.run(until=c.sim.now + 0.1)

    # SILENT DATA CORRUPTION: the app's new value 55 was overwritten by the
    # stale 77, and the recycled flag makes it look valid
    assert ll.poll_slot(0, 1) == 77, "expected the corruption to manifest"
