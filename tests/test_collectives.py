"""JCCL collective correctness — with and without failure injection."""

import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.core import shift as S
from repro.core import verbs as V
from repro.core.fabric import build_cluster
from repro.collectives import JcclWorld, CollectiveError


def make_world(n_ranks=4, lib_kind="shift", nics_per_host=2,
               probe_interval=5e-3, max_chunk_bytes=1 << 16):
    c = build_cluster(n_hosts=n_ranks, nics_per_host=nics_per_host)
    if lib_kind == "shift":
        cfg = S.ShiftConfig(probe_interval=probe_interval)
        kv = None
        libs = []
        for r in range(n_ranks):
            lib = S.ShiftLib(c, f"host{r}", kv=kv, config=cfg)
            kv = lib.kv
            libs.append(lib)
    else:
        libs = [S.StandardLib(c, f"host{r}") for r in range(n_ranks)]
    world = JcclWorld(c, libs, max_chunk_bytes=max_chunk_bytes)
    return c, world


def test_allreduce_exact_small():
    c, w = make_world(n_ranks=4)
    arrays = [np.arange(1000, dtype=np.int64) * (r + 1) for r in range(4)]
    expect = sum(a.copy() for a in arrays)
    w.allreduce(arrays)
    for a in arrays:
        np.testing.assert_array_equal(a, expect)


def test_allreduce_multibucket():
    c, w = make_world(n_ranks=2, max_chunk_bytes=4096)
    n = 4096 * 5 + 37  # forces several buckets + ragged tail
    arrays = [np.ones(n, dtype=np.float32) * (r + 1) for r in range(2)]
    w.allreduce(arrays)
    for a in arrays:
        np.testing.assert_allclose(a, 3.0)


def test_allgather_and_broadcast_and_a2a():
    c, w = make_world(n_ranks=4)
    shards = [np.full(17 + r, r, dtype=np.float32) for r in range(4)]
    full = w.all_gather(shards)
    expect = np.concatenate(shards)
    for f in full:
        np.testing.assert_array_equal(f, expect)

    msg = np.arange(5000, dtype=np.float32)
    outs = w.broadcast(msg, root=2)
    for o in outs:
        np.testing.assert_array_equal(o, msg)

    mats = [np.arange(4 * 8, dtype=np.int64).reshape(4, 8) + 100 * r
            for r in range(4)]
    outs = w.all_to_all(mats)
    for j in range(4):
        for i in range(4):
            np.testing.assert_array_equal(outs[j][i], mats[i][j])


def test_reduce_scatter_owned_chunks():
    c, w = make_world(n_ranks=4)
    arrays = [np.arange(64, dtype=np.int64) for _ in range(4)]
    owned = w.reduce_scatter(arrays)
    full = np.arange(64, dtype=np.int64) * 4
    per = 16
    for r in range(4):
        own = (r + 1) % 4
        np.testing.assert_array_equal(owned[r], full[own * per:(own + 1) * per])


def test_allreduce_survives_nic_failure_mid_collective():
    c, w = make_world(n_ranks=4, max_chunk_bytes=8192)
    n = 8192 * 6  # enough steps that the failure lands mid-collective
    arrays = [np.ones(n, dtype=np.float64) * (r + 1) for r in range(4)]
    # kill host1's rail-0 NIC shortly after the collective starts (the
    # bucket-parallel rings finish in ~230us of virtual time, so the
    # fault must land well inside that window)
    c.sim.at(c.sim.now + 1e-4, c.fail_nic, "host1/mlx5_0")
    w.allreduce(arrays)
    for a in arrays:
        np.testing.assert_allclose(a, 10.0)
    assert any(lib.stats.fallbacks > 0
               for lib in (ep.lib for ep in w.endpoints))


def test_allreduce_survives_flap_and_switches_back():
    c, w = make_world(n_ranks=2, max_chunk_bytes=8192, probe_interval=2e-3)
    n = 8192 * 8
    arrays = [np.full(n, float(r + 1), dtype=np.float64) for r in range(2)]
    t0 = c.sim.now
    c.flap_nic("host0/mlx5_0", down_at=t0 + 2e-4, up_at=t0 + 8e-3)
    w.allreduce(arrays)
    for a in arrays:
        np.testing.assert_allclose(a, 3.0)
    # run on; a later collective should use the recovered default path
    c.sim.run(until=c.sim.now + 0.1)
    arrays2 = [np.full(1024, float(r + 1), dtype=np.float64) for r in range(2)]
    w.allreduce(arrays2)
    for a in arrays2:
        np.testing.assert_allclose(a, 3.0)
    libs = [ep.lib for ep in w.endpoints]
    assert any(lib.stats.recoveries > 0 for lib in libs)


def test_standard_world_aborts_on_failure():
    c, w = make_world(n_ranks=2, lib_kind="standard", max_chunk_bytes=8192)
    n = 8192 * 8
    arrays = [np.ones(n, dtype=np.float64) for _ in range(2)]
    c.sim.at(c.sim.now + 2e-4, c.fail_nic, "host1/mlx5_0")
    with pytest.raises(CollectiveError):
        w.allreduce(arrays, timeout=5.0)


@given(fail_t=st.floats(min_value=5e-5, max_value=2e-3),
       victim=st.sampled_from(["host0/mlx5_0", "host1/mlx5_0",
                               "host2/mlx5_0"]))
@settings(max_examples=10, deadline=None)
def test_allreduce_exact_under_any_failure_timing(fail_t, victim):
    """Property: the all-reduce result is bit-exact no matter when (or
    which) NIC dies — SHIFT's §3.2 guarantee at collective level."""
    V.reset_registries()
    c, w = make_world(n_ranks=3, max_chunk_bytes=4096)
    n = 4096 * 4
    arrays = [(np.arange(n, dtype=np.int64) % 97) * (r + 1) for r in range(3)]
    expect = sum(a.copy() for a in arrays)
    c.sim.at(c.sim.now + fail_t, c.fail_nic, victim)
    w.allreduce(arrays)
    for a in arrays:
        np.testing.assert_array_equal(a, expect)
